"""Chrome trace-event export: see where every step's cycles go.

:class:`ChromeTracer` collects structured events from the serving stack
and exports them as Chrome trace-event JSON (the ``traceEvents`` array
format) — load the file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` and the serve path renders as a timeline:

* **engine track** (tid 0): one ``B``/``E`` pair per ``ServeEngine.step``
  with nested ``admit`` / ``prefill`` / ``decode`` phase spans;
* **one track per lane** (tid 1..n_slots): ``X`` (complete) spans for
  each chunked-prefill and decode-step dispatch the lane took part in,
  tagged with the owning request id;
* **scheduler track**: instants for admissions, preemptions and sheds;
* **prefix-cache track**: instants for hits / misses / inserts /
  evictions / COW forks;
* **pages track**: a ``C`` (counter) series of free vs cache-resident
  pages — pool pressure over time.

Timestamps are microseconds on the telemetry clock, relative to tracer
construction, so host spans line up with each other exactly; with
``jax_annotations`` enabled the same dispatch sites also carry
``jax.profiler.TraceAnnotation`` scopes so the host timeline can be
aligned with an XLA device profile captured by ``jax.profiler.trace``.

The event buffer is bounded (``max_events``): a runaway run drops
events past the cap (counted in ``dropped``) instead of eating the
host's memory — the exported metadata records the truncation.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

# fixed track ids: lanes are 1..n_slots, service tracks sit far above
# any plausible lane count so the ids never collide
ENGINE_TID = 0
SCHED_TID = 1000
CACHE_TID = 1001
PAGES_TID = 1002
MEM_TID = 1003  # "memory" track: pool occupancy/evictable/cached per step

REQUIRED_KEYS = ("ph", "ts", "pid", "tid", "name")


class ChromeTracer:
    """Bounded collector of Chrome trace events on an injectable clock."""

    def __init__(self, clock, pid: int = 1, max_events: int = 500_000):
        self._clock = clock
        self._t0 = clock()
        self.pid = pid
        self.max_events = max_events
        self.events: List[Dict] = []
        self.dropped = 0
        self._named_tids = set()

    # ------------------------------------------------------------- plumbing
    def ts(self, t: Optional[float] = None) -> float:
        """Microseconds since tracer start (trace-relative)."""
        return ((self._clock() if t is None else t) - self._t0) * 1e6

    def _push(self, ev: Dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def thread_name(self, tid: int, name: str) -> None:
        """Label a track (idempotent; Perfetto reads these ``M`` events)."""
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._push({"ph": "M", "ts": 0, "pid": self.pid, "tid": tid,
                    "name": "thread_name", "args": {"name": name}})

    # --------------------------------------------------------------- events
    def begin(self, tid: int, name: str, args: Optional[Dict] = None,
              t: Optional[float] = None) -> None:
        ev = {"ph": "B", "ts": self.ts(t), "pid": self.pid, "tid": tid,
              "name": name}
        if args:
            ev["args"] = args
        self._push(ev)

    def end(self, tid: int, name: str, t: Optional[float] = None) -> None:
        self._push({"ph": "E", "ts": self.ts(t), "pid": self.pid,
                    "tid": tid, "name": name})

    def complete(self, tid: int, name: str, t0: float, t1: float,
                 args: Optional[Dict] = None) -> None:
        """An ``X`` span from clock readings ``t0``..``t1`` (seconds)."""
        ev = {"ph": "X", "ts": self.ts(t0), "dur": max(t1 - t0, 0.0) * 1e6,
              "pid": self.pid, "tid": tid, "name": name}
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, tid: int, name: str,
                args: Optional[Dict] = None) -> None:
        ev = {"ph": "i", "ts": self.ts(), "pid": self.pid, "tid": tid,
              "name": name, "s": "t"}
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, tid: int, name: str, values: Dict) -> None:
        self._push({"ph": "C", "ts": self.ts(), "pid": self.pid,
                    "tid": tid, "name": name, "args": dict(values)})

    # --------------------------------------------------------------- export
    def export(self) -> Dict:
        """The trace as a JSON-serializable dict (Perfetto-loadable)."""
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.export(), f)
        return path


def validate_trace(trace: Dict) -> Dict:
    """Validate a Chrome trace-event dict; raises ``ValueError`` on the
    first violation, returns per-track event counts on success.

    Checks the trace-event schema contract the tests and CI gate on:

    * every event carries ``ph``/``ts``/``pid``/``tid``/``name``;
    * ``X`` events carry a non-negative ``dur``;
    * per ``(pid, tid)`` track, ``B``/``E`` pairs nest consistently in
      timestamp order (every ``E`` closes the innermost open ``B`` of
      the same name; nothing is left open at the end);
    * timestamps never run backwards within a track's ``B``/``E`` flow.
    """
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace has no traceEvents list")
    tracks: Dict = {}
    counts: Dict[str, int] = {}
    for i, ev in enumerate(events):
        for k in REQUIRED_KEYS:
            if k not in ev:
                raise ValueError(f"event {i} missing required key {k!r}: "
                                 f"{ev}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has bad ts {ev['ts']!r}")
        key = (ev["pid"], ev["tid"])
        counts[f"{key[0]}/{key[1]}"] = counts.get(f"{key[0]}/{key[1]}", 0) + 1
        if ev["ph"] == "X":
            if ev.get("dur", -1) < 0:
                raise ValueError(f"X event {i} has bad dur: {ev}")
            continue
        if ev["ph"] not in ("B", "E"):
            continue
        stack, last_ts = tracks.setdefault(key, ([], [0.0]))
        if ev["ts"] < last_ts[0] - 1e-6:
            raise ValueError(
                f"track {key} B/E ts ran backwards at event {i}: "
                f"{ev['ts']} < {last_ts[0]}")
        last_ts[0] = ev["ts"]
        if ev["ph"] == "B":
            stack.append(ev["name"])
        else:
            if not stack:
                raise ValueError(f"track {key} E without open B: {ev}")
            opened = stack.pop()
            if opened != ev["name"]:
                raise ValueError(
                    f"track {key} E {ev['name']!r} closes B {opened!r}")
    for key, (stack, _) in tracks.items():
        if stack:
            raise ValueError(f"track {key} left spans open: {stack}")
    return counts
