"""Per-request span timelines: every lifecycle transition, timestamped.

A :class:`RequestTimeline` is the observability twin of a
``serve.engine.Request``: the engine/scheduler stamp each transition
through the telemetry layer and the timeline accumulates them as
``(state, t)`` events, from which the *correct* per-request latency
decomposition falls out:

* ``queue_wait`` — submit → first admission;
* ``ttft``       — submit → first emitted token (per-request, **not**
  relative to the engine's run start — the bug this PR fixed);
* ``tpot``       — mean inter-token gap after the first token;
* ``e2e``        — submit → terminal state.

State machine (terminal states in caps)::

    submitted -> queued -> admitted -> prefilling -> decoding -> RETIRED
                   ^           |            |            |   \\-> CANCELLED
                   |           +------------+------------+   \\-> TIMED_OUT
                   +------ preempted (pages reclaimed, re-queued)

Preemption loops back: a preempted request re-enters ``queued`` and is
re-admitted later; its timeline keeps every pass, so preemption cost is
visible per request (``n_preemptions``, time spent re-prefilling).
Shed requests never get a timeline — they are refused before a
``Request`` (and thus an rid) exists; the registry counts them by
reason and the tracer drops an instant on the scheduler track.

Timestamps come from the telemetry's injectable clock, so tests drive
transitions deterministically with a manual clock.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

# canonical state names (timeline events and Chrome-trace args use these)
SUBMITTED = "submitted"
QUEUED = "queued"
ADMITTED = "admitted"
PREFILLING = "prefilling"
DECODING = "decoding"
PREEMPTED = "preempted"
RETIRED = "retired"
CANCELLED = "cancelled"
TIMED_OUT = "timed_out"
# quarantined after a step fault / non-finite logits exhausted the
# request's retry budget (finish_reason="error"); like PREEMPTED, a
# *retried* fault is not terminal — the request loops back to QUEUED
ERRORED = "errored"

TERMINAL = (RETIRED, CANCELLED, TIMED_OUT, ERRORED)


class RequestTimeline:
    """One request's timestamped lifecycle (host-side, bounded).

    ``events`` holds every ``(state, t)`` transition in order;
    ``prefill_spans`` the per-chunk ``(t0, t1, n_tokens)`` work spans.
    Token *times* are not stored per token (unbounded); instead the
    owning telemetry folds inter-token gaps into its ``serve_tpot_s``
    histogram and the timeline keeps first/last token plus the count.
    """

    __slots__ = ("rid", "submit_t", "events", "prefill_spans",
                 "first_token_t", "last_token_t", "n_tokens", "end_t",
                 "n_preemptions", "cached_tokens")

    def __init__(self, rid: int, submit_t: float):
        self.rid = rid
        self.submit_t = submit_t
        self.events: List[Tuple[str, float]] = [(SUBMITTED, submit_t),
                                                (QUEUED, submit_t)]
        self.prefill_spans: List[Tuple[float, float, int]] = []
        self.first_token_t: Optional[float] = None
        self.last_token_t: Optional[float] = None
        self.n_tokens = 0
        self.end_t: Optional[float] = None
        self.n_preemptions = 0
        self.cached_tokens = 0

    # ------------------------------------------------------------ recording
    def transition(self, state: str, t: float) -> None:
        self.events.append((state, t))
        if state == PREEMPTED:
            self.n_preemptions += 1
            self.events.append((QUEUED, t))
        if state in TERMINAL:
            self.end_t = t

    def token(self, t: float) -> None:
        if self.first_token_t is None:
            self.first_token_t = t
        self.last_token_t = t
        self.n_tokens += 1

    # --------------------------------------------------------------- views
    @property
    def state(self) -> str:
        return self.events[-1][0]

    @property
    def finished(self) -> bool:
        return self.state in TERMINAL

    def first(self, state: str) -> Optional[float]:
        for s, t in self.events:
            if s == state:
                return t
        return None

    @property
    def queue_wait(self) -> Optional[float]:
        t = self.first(ADMITTED)
        return None if t is None else t - self.submit_t

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def tpot(self) -> Optional[float]:
        if self.n_tokens < 2:
            return None
        return ((self.last_token_t - self.first_token_t)
                / (self.n_tokens - 1))

    @property
    def e2e(self) -> Optional[float]:
        return None if self.end_t is None else self.end_t - self.submit_t

    def prefill_tokens_computed(self) -> int:
        return sum(n for _, _, n in self.prefill_spans)

    def to_dict(self) -> Dict:
        return {
            "rid": self.rid,
            "state": self.state,
            "events": [(s, round(t, 6)) for s, t in self.events],
            "prefill_spans": [
                (round(t0, 6), round(t1, 6), n)
                for t0, t1, n in self.prefill_spans],
            "n_tokens": self.n_tokens,
            "n_preemptions": self.n_preemptions,
            "cached_tokens": self.cached_tokens,
            "queue_wait_s": self.queue_wait,
            "ttft_s": self.ttft,
            "tpot_s": self.tpot,
            "e2e_s": self.e2e,
        }
