"""``EnginePlan`` — the single resolved dispatch object of the GEMV engine.

A plan is resolved **once** per run (from :class:`EngineConfig`, at
``ServeEngine`` construction / dry-run cell build / benchmark setup) and
then threaded as one value through ``models.layers.dense``, the serving
engine, the launch cells and the benchmarks.  Everything the hot path needs
is pinned here: the backend, the digit radix, kernel tile sizes and the
output dtype.  No call-site decides ``use_pallas`` / ``interpret`` booleans
anymore — that decision lives in the backend registry.

``resolve_plan`` is memoized on the (frozen, hashable) ``EngineConfig``, so
"resolved once" is literal: repeated calls with the same config return the
same plan object.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax.numpy as jnp

from repro.engine.backends import (
    get_backend,
    resolve_attn_backend,
    resolve_backend_name,
)
from repro.engine.packed import PackedLinear, as_packed, validate_bits


@dataclasses.dataclass(frozen=True)
class EnginePlan:
    """Fully-resolved engine dispatch: who computes, at what precision.

    ``backend``: concrete registry name (never ``"auto"``).
    ``bits``: configured weight precision — used when *packing* weights;
        at apply time the weight container's own ``bits`` is authoritative.
        0 means "weights stay dense" and is only valid on a plan that
        quantizes something else (``kv_bits > 0``) — plan resolution
        returns None when nothing at all is quantized.
    ``radix``: weight bits retired per bit-serial pass (1 = IMAGine radix-2
        baseline, 2 = slice4/Booth-radix-4, 4 = nibble pass).
    ``kv_bits``: beyond-paper bit-planed KV cache (0 = off, 8 = int8).
    ``attn_backend``: paged-attention read path (decode *and* chunked
        prefill) — ``gather`` (materialize the logical KV view, the
        reference) or the fused in-place kernel (``pallas_interpret`` /
        ``pallas_tpu``); ``auto`` resolves like the GEMV backend (TPU →
        ``pallas_tpu``, else ``gather``), mesh or no mesh — on a
        mesh-carrying plan the kernel shard_maps over ``model_axis``
        (heads are the ``model``-sharded dim of the page pool), so
        sharded TPU plans run fused by default.  Stored concrete, never
        ``"auto"``.
    ``out_dtype``: None means "match the activation dtype".
    ``block_*``: Pallas kernel tile sizes (batch, PE-column, K-stream).

    Mesh-native fields (the ``sharded`` backend — see ``docs/sharding.md``):
    ``mesh``: the ``jax.sharding.Mesh`` the sharded backend ``shard_map``s
        over (None degrades to the wrapped backend unsharded).
    ``model_axis``: mesh axis name the weight bit-planes shard over.
    ``inner_backend``: concrete registry name the sharded backend wraps
        (resolved eagerly, like ``backend``; only set on sharded plans).
    ``psum_bits``: row-parallel partial-GEMV reduction precision — 0 is an
        exact fp32 ``psum``, 4/8 route through ``compressed_psum_leaf``.
    """

    backend: str
    bits: int
    radix: int = 1
    kv_bits: int = 0
    attn_backend: str = "auto"
    out_dtype: Any = None
    block_b: int = 128
    block_n: int = 256
    block_k: int = 512
    mesh: Any = None
    model_axis: str = "model"
    inner_backend: Optional[str] = None
    psum_bits: int = 0

    def __post_init__(self):
        if self.kv_bits not in (0, 8):
            raise ValueError(f"kv_bits must be 0/8, got {self.kv_bits}")
        if self.bits or not self.kv_bits:
            validate_bits(self.bits)  # bits=0 only on a kv-only plan
        if self.radix not in (1, 2, 4, 8):
            raise ValueError(f"radix must be 1/2/4/8, got {self.radix}")
        if self.bits % self.radix != 0:
            raise ValueError(
                f"radix {self.radix} must divide bits {self.bits}")
        if self.psum_bits not in (0, 4, 8):
            raise ValueError(
                f"psum_bits must be 0/4/8, got {self.psum_bits}")
        # resolve + validate the backend name eagerly: a typo fails at plan
        # resolution, not in the middle of a jitted decode step.
        object.__setattr__(
            self, "backend", resolve_backend_name(self.backend))
        object.__setattr__(
            self, "attn_backend",
            resolve_attn_backend(self.attn_backend, mesh=self.mesh))
        if self.backend == "sharded":
            inner = resolve_backend_name(self.inner_backend)
            if inner == "sharded":
                raise ValueError(
                    "the sharded backend cannot wrap itself; pick a "
                    "single-device inner_backend")
            object.__setattr__(self, "inner_backend", inner)
            if (self.mesh is not None
                    and self.model_axis
                    not in getattr(self.mesh, "axis_names", ())):
                raise ValueError(
                    f"model_axis {self.model_axis!r} not in mesh axes "
                    f"{tuple(getattr(self.mesh, 'axis_names', ()))}")

    # ------------------------------------------------------------------ api
    def apply(self, lin, x: jnp.ndarray, *, out_dtype=None) -> jnp.ndarray:
        """``y = x @ W [+ bias]`` through this plan's backend.

        ``lin`` may be a :class:`PackedLinear` or any legacy container
        (``QuantizedLinear``, ``{"packed", "scale"}`` dict) — normalized
        here, with this plan's ``bits`` as the hint for bit-less legacy
        dicts.  ``x``: ``(..., in_features)``; 1D inputs are treated as a
        single row and squeezed back.
        """
        lin = as_packed(lin, bits_hint=self.bits)
        od = out_dtype or self.out_dtype or x.dtype
        squeeze = x.ndim == 1
        if squeeze:
            x = x[None, :]
        y = get_backend(self.backend)(self, lin, x, od)
        if lin.bias is not None:
            y = y + lin.bias.astype(y.dtype)
        return y[0] if squeeze else y

    def pack(self, w: jnp.ndarray, *, bias=None) -> PackedLinear:
        """Pack a float weight at this plan's configured precision."""
        from repro.engine.packed import pack_linear

        return pack_linear(w, self.bits, bias=bias)

    def replace(self, **kw) -> "EnginePlan":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# resolution from config
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _resolve_cached(cfg, backend: Optional[str], mesh) -> Optional[EnginePlan]:
    # kv_bits alone enables the engine: the resulting plan carries bits=0
    # (dense weights) but routes the KV cache through int8 pages — the
    # quantized cache runs the same dispatch layer as the weights.
    if not cfg.enabled and not getattr(cfg, "kv_bits", 0):
        return None
    name = backend or getattr(cfg, "backend", "auto") or "auto"
    inner = None
    if getattr(cfg, "sharded", False) and name != "sharded":
        # cfg.backend names the *wrapped* backend; "sharded" is the
        # mesh-native dispatch around it.
        inner = resolve_backend_name(name)
        name = "sharded"
    return EnginePlan(
        backend=resolve_backend_name(name),
        bits=cfg.weight_bits,
        radix=cfg.radix,
        kv_bits=cfg.kv_bits,
        attn_backend=getattr(cfg, "attn_backend", "auto") or "auto",
        block_n=cfg.tile_m,
        block_k=cfg.tile_k,
        mesh=mesh,
        inner_backend=inner,
        psum_bits=getattr(cfg, "psum_bits", 0),
    )


def resolve_plan(cfg, *, backend: Optional[str] = None,
                 mesh=None) -> Optional[EnginePlan]:
    """``EngineConfig`` (or None) -> resolved ``EnginePlan`` (or None).

    None / a fully-disabled config (``weight_bits == 0`` *and*
    ``kv_bits == 0``) resolve to None — the plain dense path.  A
    kv-only config (``weight_bits=0, kv_bits=8``) resolves to a live
    plan with ``bits=0`` (dense weights, int8 KV pages).  ``backend``
    overrides the config's backend field.  ``mesh`` pins the production
    mesh into the plan (the ``sharded`` backend needs one; resolution
    is memoized per (config, backend, mesh) — ``jax.sharding.Mesh`` is
    hashable).  Passing an already-resolved plan returns it unchanged.
    """
    if cfg is None:
        return None
    if isinstance(cfg, EnginePlan):
        return cfg
    return _resolve_cached(cfg, backend, mesh)


def as_plan(eng) -> Optional[EnginePlan]:
    """Normalize the model-path ``eng`` argument (EngineConfig | EnginePlan
    | None) into an Optional[EnginePlan].  The one entry point model code
    calls; cached, so threading it per-forward is free."""
    return resolve_plan(eng)


def plan_for_bits(bits: int, *, backend: str = "auto") -> EnginePlan:
    """A standalone plan (no config) — e.g. for a weight packed directly."""
    return EnginePlan(backend=resolve_backend_name(backend), bits=bits)
