"""``PackedLinear`` — the one weight format of the IMAGine GEMV engine.

Historically the engine had two incompatible weight containers:

  * ``repro.core.gemv_engine.QuantizedLinear`` (a NamedTuple) on the
    kernel-facing path, and
  * ad-hoc ``{"packed", "scale", "bits"?}`` param dicts emitted by
    ``repro.models.transformer.quantize_params`` on the model path.

``PackedLinear`` replaces both: a frozen dataclass registered as a JAX
pytree, so it survives ``jax.jit``, ``jax.lax.scan`` over stacked layers,
``jax.tree.map``, ``jax.eval_shape`` and checkpointing.  ``packed`` /
``scale`` / ``bias`` are traced leaves; ``bits`` and the feature sizes are
static metadata carried through every transformation.

``bits`` is validated once, at pack time, and is *authoritative*: every
backend reads the precision from the weight container, never from a config
default (the old code silently fell back to 8 when no config was passed).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import pack_weights, unpack_weights
from repro.core.quantize import quantize_symmetric

VALID_BITS = (2, 4, 8)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("packed", "scale", "bias"),
    meta_fields=("bits", "in_features", "out_features", "partition"),
)
@dataclasses.dataclass(frozen=True)
class PackedLinear:
    """Weight-stationary bit-packed linear: ``y = x @ W [+ bias]``.

    ``packed``: int8, ``(..., in_features * bits // 8, out_features)`` —
    the contraction (K) axis is bit-packed, so HBM holds exactly ``bits/8``
    bytes per weight (the paper's memory-capacity scaling argument).
    Leading axes, if any, are stacked layers / experts.
    ``scale``: float32, ``(..., 1, out_features)`` per-output-channel scales.
    ``bias``: optional float, ``(..., out_features)``.
    ``bits``: static python int in ``{2, 4, 8}``.
    ``partition``: preferred mesh partitioning for the sharded backend —
    ``"col"`` / ``"row"`` / None (auto).  Set by ``quantize_params`` from
    the weight's name so the shard_map specs agree with the name-based
    ``dist.sharding`` placement (a ``wo`` placed row-parallel must not be
    re-gathered column-parallel inside every decode step).
    """

    packed: jnp.ndarray
    scale: jnp.ndarray
    bias: Optional[jnp.ndarray] = None
    bits: int = 8
    in_features: int = 0
    out_features: int = 0
    partition: Optional[str] = None

    # -------------------------------------------------------------- helpers
    @property
    def per_byte(self) -> int:
        return 8 // self.bits

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        """Reconstruct the float weight matrix (K-axis unpacked)."""
        q = unpack_weights(self.packed, self.bits, axis=-2)
        return (q.astype(jnp.float32) * self.scale).astype(dtype)

    def nbytes(self) -> int:
        n = self.packed.size * self.packed.dtype.itemsize
        n += self.scale.size * self.scale.dtype.itemsize
        if self.bias is not None:
            n += self.bias.size * self.bias.dtype.itemsize
        return int(n)


def validate_bits(bits: Any) -> int:
    if bits is None:
        raise ValueError(
            "engine weight precision is unset: PackedLinear.bits is "
            "authoritative and must be one of {2, 4, 8} (0 means 'engine "
            "disabled' and is only valid on EngineConfig.weight_bits)")
    bits = int(bits)
    if bits not in VALID_BITS:
        raise ValueError(f"bits must be one of {VALID_BITS}, got {bits}")
    return bits


def pack_linear(
    w: jnp.ndarray,
    bits: int = 8,
    *,
    bias: Optional[jnp.ndarray] = None,
    partition: Optional[str] = None,
) -> PackedLinear:
    """Quantize + bit-pack a float ``(..., K, N)`` weight into engine form.

    ``partition``: optional ``"col"`` / ``"row"`` preference for the
    sharded backend (see :class:`PackedLinear`).
    """
    bits = validate_bits(bits)
    if partition not in (None, "col", "row"):
        raise ValueError(
            f"partition must be 'col', 'row' or None, got {partition!r}")
    if w.ndim < 2:
        raise ValueError(f"weight must be at least 2D (K, N), got {w.shape}")
    k, n = w.shape[-2], w.shape[-1]
    if (k * bits) % 8 != 0:
        raise ValueError(
            f"in_features {k} * bits {bits} must pack into whole int8 words")
    q, scale = quantize_symmetric(w, bits, axis=-2)
    packed = pack_weights(q, bits, axis=-2)
    return PackedLinear(packed, scale, bias, bits, k, n, partition)


def as_packed(p: Any, *, bits_hint: Optional[int] = None) -> PackedLinear:
    """Normalize any legacy engine weight container into ``PackedLinear``.

    Accepts ``PackedLinear`` (identity), the deprecated ``QuantizedLinear``
    NamedTuple, and the deprecated ``{"packed", "scale"[, "bits", "bias"]}``
    param dict.  A legacy dict that carries no ``bits`` key must be paired
    with an explicit ``bits_hint`` (from an :class:`EnginePlan`) — there is
    no silent default-to-8 anymore.
    """
    if isinstance(p, PackedLinear):
        return p
    # QuantizedLinear and other NamedTuple-likes with the same fields
    if hasattr(p, "packed") and hasattr(p, "scale") and hasattr(p, "bits"):
        bits = validate_bits(p.bits)
        k = getattr(p, "in_features", p.packed.shape[-2] * (8 // bits))
        n = getattr(p, "out_features", p.packed.shape[-1])
        return PackedLinear(p.packed, p.scale, None, bits, k, n)
    if isinstance(p, dict) and "packed" in p:
        bits = p.get("bits", bits_hint)
        bits = validate_bits(bits)
        packed = p["packed"]
        k = packed.shape[-2] * (8 // bits)
        n = packed.shape[-1]
        return PackedLinear(packed, p["scale"], p.get("bias"), bits, k, n)
    raise TypeError(
        f"cannot interpret {type(p).__name__} as an engine PackedLinear")


def partition_kind(lin: PackedLinear, msize: int) -> str:
    """How one packed weight shards over a model axis of size ``msize``.

    ``lin.partition`` states a preference (from the weight's name — the
    same rule ``dist.sharding`` places it by) and wins whenever its axis
    divides.  Otherwise ``"col"`` is preferred over ``"row"`` (no
    collective): the output-feature axis splits evenly.  ``"row"``
    requires both the packed int8 rows *and* the unpacked feature count
    to divide, so every shard unpacks whole features.  ``"replicate"``:
    stacked-expert weights, trivial meshes, or nothing divisible — the
    degrade-to-replication rule of ``repro.dist.sharding``, never an
    error.
    """
    if lin.packed.ndim != 2 or msize <= 1:
        return "replicate"
    col_ok = lin.out_features > 0 and lin.out_features % msize == 0
    kp = lin.packed.shape[-2]
    row_ok = (kp % msize == 0 and lin.in_features > 0
              and lin.in_features % msize == 0)
    if lin.partition == "row" and row_ok:
        return "row"
    if lin.partition == "col" and col_ok:
        return "col"
    if col_ok:
        return "col"
    if row_ok:
        return "row"
    return "replicate"


def as_param_dict(lin: PackedLinear) -> dict:
    """Back-compat view for code still expecting the legacy dict format."""
    out = {"packed": lin.packed, "scale": lin.scale, "bits": lin.bits}
    if lin.bias is not None:
        out["bias"] = lin.bias
    return out


def is_packed(p: Any) -> bool:
    """True for any engine weight container (new or legacy)."""
    return (
        isinstance(p, PackedLinear)
        or (isinstance(p, dict) and "packed" in p)
        or (hasattr(p, "packed") and hasattr(p, "scale")
            and hasattr(p, "bits"))
    )
