"""``sharded`` — the mesh-native engine backend.

IMAGine's core scaling claim is that GEMV throughput tracks the number of
memory banks holding weight bit-planes.  This backend is that claim at pod
scale: it ``shard_map``s a wrapped single-device backend
(``plan.inner_backend``) over the plan's ``model_axis``, so each device
owns a contiguous slice of the bit-packed weight and runs the GEMV for its
slice only — the Balanced-Data-Placement rule (rows spread over banks) and
the UPMEM lesson (reduce partials next to the data) in one dispatch entry.

Partitioning follows ``repro.dist.sharding``'s divisibility discipline
(:func:`repro.engine.packed.partition_kind`):

* **column-parallel** (preferred — no collective): the output-feature axis
  of ``packed``/``scale`` is sharded, activations are replicated, and the
  result reassembles model-sharded along its feature axis.
* **row-parallel**: the packed contraction axis and the activation feature
  axis are sharded; each device produces a partial GEMV reduced with
  :func:`repro.dist.collectives.psum_partial` (exact fp32 ``psum``, or
  ``compressed_psum_leaf`` codes when ``plan.psum_bits`` is 4/8).
* anything non-divisible — stacked expert weights, trivial meshes, a plan
  with no mesh — degrades to the wrapped backend unsharded, mirroring the
  degrade-to-replication rule of the param specs.  Never an error.

With ``psum_bits == 0`` both partitionings are bit-for-bit against the
wrapped backend whenever the per-slice fp32 accumulations are exact
(integer activation/weight grids — ``tests/test_shard_engine.py`` pins
this on an 8-device host mesh).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import psum_partial
from repro.dist.sharding import paged_attn_partition
from repro.engine.backends import get_backend, register_backend
from repro.engine.packed import PackedLinear, partition_kind


def _mesh_axis_size(mesh, axis: str) -> int:
    try:
        return dict(mesh.shape).get(axis, 1)
    except Exception:
        return 1


def _batch_entry(mesh, model_axis: str, x: jnp.ndarray):
    """Data-axes spec entry for x's leading (batch) axis, or None.

    Serving activations are lanes-over-data; declaring that in the
    shard_map specs keeps each data shard computing its own lanes instead
    of all-gathering the batch before every GEMV.  Degrades to
    replication when the batch does not divide (shard_map specs, unlike
    hints, hard-require divisibility).
    """
    sizes = dict(mesh.shape)
    daxes = tuple(a for a in ("pod", "data")
                  if a in sizes and a != model_axis)
    prod = 1
    for a in daxes:
        prod *= sizes[a]
    if x.ndim < 2 or prod <= 1 or x.shape[0] % prod != 0:
        return None
    return daxes if len(daxes) > 1 else daxes[0]


@register_backend("sharded")
def _sharded(plan, lin: PackedLinear, x: jnp.ndarray, out_dtype):
    inner = get_backend(plan.inner_backend or "reference")
    mesh, axis = plan.mesh, plan.model_axis
    msize = _mesh_axis_size(mesh, axis) if mesh is not None else 1
    kind = partition_kind(lin, msize)
    if mesh is None or kind == "replicate":
        return inner(plan, lin, x, out_dtype)

    bits, k, n = lin.bits, lin.in_features, lin.out_features
    lead = (_batch_entry(mesh, axis, x),) + (None,) * (x.ndim - 2)

    if kind == "col":
        # W columns over the model axis: x replicated, no collective; the
        # output comes back model-sharded along its feature axis.
        def col(packed, scale, xx):
            loc = PackedLinear(packed, scale, None, bits, k, n // msize)
            return inner(plan, loc, xx, out_dtype)

        return shard_map(
            col, mesh=mesh,
            in_specs=(P(None, axis), P(None, axis), P(*lead, None)),
            out_specs=P(*lead, axis),
            check_rep=False,
        )(lin.packed, lin.scale, x)

    # row-parallel: K (packed rows + activation features) over the model
    # axis; partial GEMVs accumulate in fp32 and reduce close to the data.
    def row(packed, scale, xx):
        loc = PackedLinear(packed, scale, None, bits, k // msize, n)
        part = inner(plan, loc, xx, jnp.float32)
        return psum_partial(part, axis, bits=plan.psum_bits).astype(
            out_dtype)

    return shard_map(
        row, mesh=mesh,
        in_specs=(P(axis, None), P(None, None), P(*lead, axis)),
        out_specs=P(*lead, None),
        check_rep=False,
    )(lin.packed, lin.scale, x)


# ---------------------------------------------------------------------------
# fused paged attention under shard_map (decode + chunked prefill)
# ---------------------------------------------------------------------------


def sharded_paged_attention(mesh, model_axis, qg, k_pages, v_pages,
                            block_tables, pos, win, k_scale, v_scale, *,
                            interpret: bool, prefill=None):
    """shard_map the fused paged-attention kernel over the mesh.

    KV heads are already the ``model``-sharded dim of the page pool
    (``dist.sharding.cache_shardings``), and softmax is per-head, so each
    per-shard kernel invocation runs on the contiguous head slice its
    shard holds — no in-kernel collective.  Queries arrive grouped
    (decode ``(B, Hkv, G, D)``, prefill ``(B, Hkv, Cp, G, D)``): axis 1
    is the KV-head axis on both, so one head entry shards queries, pools
    and scale pools alike.  Lanes (queries, block tables, positions)
    shard over the data axes when the batch divides.

    The *page* axis stays replicated inside the kernel: a lane's block
    table may point at any physical page, so the pages-over-data placement
    is undone (an all-gather over the data axes within each model group)
    before the per-shard kernel runs — the same logical traffic the
    gather backend's cross-shard ``jnp.take`` pays, without the gathered
    view write/read.  Non-divisible heads/batch degrade to replication
    (``paged_attn_partition``), never an error.

    ``prefill``: None runs the decode kernel (``pos`` = ``cur_pos``);
    a ``dict(seq_lens=..., chunk=..., block_q=...)`` runs the prefill
    grid (``pos`` = ``pos0``).
    """
    from repro.kernels.paged_attention.kernel import (
        paged_attention_pallas,
        paged_prefill_pallas,
    )

    head, lane = paged_attn_partition(
        mesh, model_axis, k_pages.shape[2], qg.shape[0])
    q_tail = (None,) * (qg.ndim - 2)
    q_spec = P(lane, head, *q_tail)
    pool = P(None, None, head, None)
    scale_p = P(None, None, head)
    bt_s, lane_s, win_s = P(lane, None), P(lane), P(None)
    quant = k_scale is not None

    if prefill is None:
        def run(qg, kp, vp, bt, pos, win, *scales):
            ks, vs = scales if quant else (None, None)
            return paged_attention_pallas(qg, kp, vp, bt, pos, win, ks, vs,
                                          interpret=interpret)

        in_specs = (q_spec, pool, pool, bt_s, lane_s, win_s)
        operands = (qg, k_pages, v_pages, block_tables, pos, win)
    else:
        seq_lens = prefill["seq_lens"]
        chunk, block_q = prefill["chunk"], prefill["block_q"]

        def run(qg, kp, vp, bt, pos, seq, win, *scales):
            ks, vs = scales if quant else (None, None)
            return paged_prefill_pallas(qg, kp, vp, bt, pos, seq, win,
                                        ks, vs, chunk=chunk,
                                        block_q=block_q,
                                        interpret=interpret)

        in_specs = (q_spec, pool, pool, bt_s, lane_s, lane_s, win_s)
        operands = (qg, k_pages, v_pages, block_tables, pos, seq_lens, win)
    if quant:
        in_specs = in_specs + (scale_p, scale_p)
        operands = operands + (k_scale, v_scale)

    return shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=q_spec,
                     check_rep=False)(*operands)
