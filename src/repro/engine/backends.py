"""Backend registry for the IMAGine GEMV engine.

One dispatch table replaces the ``use_pallas=`` / ``interpret=`` boolean
pairs that used to be sprinkled over models/, serve/, launch/ and
benchmarks/.  A backend is a function

    fn(plan: EnginePlan, lin: PackedLinear, x, out_dtype) -> y

registered under a string name.  Shipped backends:

  ``reference``        pure-jnp unpack + einsum — exact, runs anywhere;
                       the dry-run lowering path.
  ``bit_serial``       explicit digit-plane walk (radix 1/2/4), numerically
                       identical to ``reference``; the FPGA-faithful oracle.
  ``pallas_interpret`` the Pallas kernel body interpreted on CPU — used to
                       validate the TPU kernel off-hardware.
  ``pallas_tpu``       the Pallas kernel compiled for TPU hardware.
  ``sharded``          mesh-native wrapper (``repro.engine.sharded``):
                       shard_maps any of the above over the plan's model
                       axis — column/row-parallel PackedLinear shards,
                       row partials psum-reduced.

``auto`` resolves from ``jax.default_backend()`` at plan-resolution time:
TPU hosts get ``pallas_tpu``, everything else gets ``reference``.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.engine.packed import PackedLinear

BackendFn = Callable[..., jnp.ndarray]

_REGISTRY: Dict[str, BackendFn] = {}

AUTO = "auto"


def register_backend(name: str, fn: BackendFn = None):
    """Register ``fn`` as engine backend ``name`` (usable as a decorator)."""
    if fn is None:
        return lambda f: register_backend(name, f)
    if not isinstance(name, str) or not name:
        raise ValueError(f"backend name must be a non-empty string: {name!r}")
    _REGISTRY[name] = fn
    return fn


def get_backend(name: str) -> BackendFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown engine backend {name!r}; available: "
            f"{sorted(_REGISTRY)}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def default_backend() -> str:
    """Auto-selection: the compiled Pallas kernel on TPU hosts, the exact
    jnp reference everywhere else (Pallas TPU kernels do not lower on the
    CPU backend)."""
    return "pallas_tpu" if jax.default_backend() == "tpu" else "reference"


def resolve_backend_name(name: str = AUTO) -> str:
    resolved = default_backend() if name in (AUTO, None, "") else name
    if resolved not in _REGISTRY:
        raise KeyError(
            f"unknown engine backend {resolved!r}; available: "
            f"{sorted(_REGISTRY)}")
    return resolved


# ---------------------------------------------------------------------------
# paged-attention backends (the decode-attention read path)
# ---------------------------------------------------------------------------

# ``gather`` is the reference read path (materialize the logical KV view,
# then attend); the pallas names run the fused in-place kernel
# (repro.kernels.paged_attention) that reads pool pages through the block
# table.  Same naming scheme as the GEMV backends so one mental model
# covers both dispatch axes of the plan.
ATTN_BACKENDS = ("gather", "pallas_interpret", "pallas_tpu")


def default_attn_backend() -> str:
    """Auto-selection for ``EnginePlan.attn_backend``: the compiled fused
    kernel on TPU hosts, the exact gather path everywhere else (interpret
    mode is a validation tool, not a CPU fast path)."""
    return "pallas_tpu" if jax.default_backend() == "tpu" else "gather"


def resolve_attn_backend(name: str = AUTO, *, mesh=None) -> str:
    """Resolve an attention-backend name; ``auto`` consults the host.

    ``auto`` resolves the same way with or without a mesh: TPU hosts get
    the fused kernel (``pallas_tpu``), everything else ``gather``.  The
    kernel shard_maps over the plan's model axis
    (``repro.engine.sharded.sharded_paged_attention`` — KV heads are
    already the ``model``-sharded dim of the page pool), so a
    mesh-carrying TPU plan now runs fused by default; the old downgrade
    of ``auto``-on-mesh to ``gather`` is gone.  ``mesh`` is still
    accepted so plan resolution reads naturally at call sites, but no
    longer changes the answer — ``gather`` stays the reference backend
    everywhere and an explicit name is always honored.
    """
    del mesh  # no longer affects resolution (kept for call-site compat)
    if name in (AUTO, None, ""):
        resolved = default_attn_backend()
    else:
        resolved = name
    if resolved not in ATTN_BACKENDS:
        raise KeyError(
            f"unknown attention backend {resolved!r}; available: "
            f"{sorted(ATTN_BACKENDS)}")
    return resolved


def default_interpret() -> bool:
    """Should Pallas kernel bodies run in interpret mode on this host?

    True everywhere except real TPU hardware.  Kernel wrappers
    (``repro.kernels.*.ops``) call this when the caller does not pin the
    mode, so the same call-site works on CPU (validation) and TPU (prod).
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """Normalize a kernel wrapper's ``interpret`` argument: None means
    "ask the registry" (:func:`default_interpret`), a bool is explicit."""
    return default_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# shipped backends
# ---------------------------------------------------------------------------


@register_backend("reference")
def _reference(plan, lin: PackedLinear, x: jnp.ndarray, out_dtype):
    """Unpack-in-register + einsum at fp32 accumulation.  Exact for b<=8.

    Handles stacked weights: a ``(..., Kp, N)`` packed tensor broadcasts
    against ``(..., B?, K)`` activations through ``jnp.matmul`` semantics —
    the MoE expert-parallel path uses ``(E, Kp, N) @ (B, E, C, K)``.
    """
    from repro.core.bitplane import unpack_weights

    q = unpack_weights(lin.packed, lin.bits, axis=-2)
    if lin.packed.ndim == 2:
        acc = jnp.einsum(
            "...k,kn->...n",
            x.astype(jnp.float32),
            q.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        )
    else:
        acc = jnp.matmul(x.astype(jnp.float32), q.astype(jnp.float32),
                         precision=jax.lax.Precision.HIGHEST)
    return (acc * lin.scale).astype(out_dtype)


@register_backend("bit_serial")
def _bit_serial(plan, lin: PackedLinear, x: jnp.ndarray, out_dtype):
    """Digit-serial oracle: walks ``radix``-bit planes of the two's
    complement code exactly like the FPGA engine retires them, the top
    digit carrying negative weight.  Numerically identical to
    ``reference``; exists so the paper's PE-variant sweep (radix-2 Booth,
    slice4, nibble-serial) has an executable host-side twin.
    """
    from repro.core.bitplane import unpack_weights

    bits, radix = lin.bits, plan.radix
    if bits % radix != 0:
        raise ValueError(f"radix {radix} must divide bits {bits}")
    q = unpack_weights(lin.packed, bits, axis=-2)
    u = q.astype(jnp.int32) & ((1 << bits) - 1)  # two's complement code
    n_digits = bits // radix
    xf = x.astype(jnp.float32)
    acc = None
    for d in range(n_digits):
        digit = (u >> (d * radix)) & ((1 << radix) - 1)
        weight = float(1 << (d * radix))
        if d == n_digits - 1:
            sign_bit = (digit >> (radix - 1)) & 1
            digit = digit - (sign_bit << radix)
        partial = jnp.matmul(xf, digit.astype(jnp.float32),
                             precision=jax.lax.Precision.HIGHEST)
        acc = weight * partial if acc is None else acc + weight * partial
    return (acc * lin.scale).astype(out_dtype)


def _pallas(plan, lin: PackedLinear, x: jnp.ndarray, out_dtype,
            interpret: bool):
    from repro.kernels.bitplane_gemv.ops import bitplane_gemv

    if lin.packed.ndim != 2 or x.ndim > 2:
        # stacked experts / batched-seq activations: the kernel is a 2D
        # GEMV tile engine; fall back to the exact jnp path.
        return _reference(plan, lin, x, out_dtype)
    return bitplane_gemv(
        lin.packed, lin.scale, x,
        bits=lin.bits, radix=plan.radix,
        block_b=plan.block_b, block_n=plan.block_n, block_k=plan.block_k,
        interpret=interpret, out_dtype=out_dtype,
    )


@register_backend("pallas_interpret")
def _pallas_interpret(plan, lin, x, out_dtype):
    return _pallas(plan, lin, x, out_dtype, interpret=True)


@register_backend("pallas_tpu")
def _pallas_tpu(plan, lin, x, out_dtype):
    return _pallas(plan, lin, x, out_dtype, interpret=False)
