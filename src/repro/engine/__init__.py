"""The IMAGine GEMV engine's single front door.

Three pieces, one API:

  * :class:`PackedLinear` — the unified bit-packed weight pytree
    (replaces ``QuantizedLinear`` and the ``{"packed", "scale"}`` dicts);
  * the backend registry — ``reference`` / ``bit_serial`` /
    ``pallas_interpret`` / ``pallas_tpu``, extensible via
    :func:`register_backend`, auto-selected from ``jax.default_backend()``;
  * :class:`EnginePlan` — resolved once from :class:`EngineConfig` via
    :func:`resolve_plan` and threaded through models / serve / launch /
    benchmarks as a single object.

Typical use::

    from repro.engine import pack_linear, resolve_plan

    plan = resolve_plan(run.serve.engine)        # once, at setup
    lin = pack_linear(w, plan.bits)              # weight-stationary pack
    y = plan.apply(lin, x)                       # hot path

Legacy entry points (``repro.core.gemv_engine.gemv`` / ``engine_dense``,
``models.layers.engine_apply``) remain as thin deprecation shims over this
package.
"""

from repro.engine.backends import (
    ATTN_BACKENDS,
    available_backends,
    default_attn_backend,
    default_backend,
    default_interpret,
    get_backend,
    register_backend,
    resolve_attn_backend,
    resolve_backend_name,
)
from repro.engine.packed import (
    PackedLinear,
    as_packed,
    as_param_dict,
    is_packed,
    pack_linear,
    partition_kind,
    validate_bits,
)
from repro.engine.plan import (
    EnginePlan,
    as_plan,
    plan_for_bits,
    resolve_plan,
)

# registers the mesh-native "sharded" backend (shard_map over the model
# axis; see docs/sharding.md) as an import side effect, exactly like the
# built-in backends above.
import repro.engine.sharded  # noqa: E402,F401  isort:skip

__all__ = [
    "ATTN_BACKENDS",
    "EnginePlan",
    "PackedLinear",
    "as_packed",
    "as_param_dict",
    "as_plan",
    "available_backends",
    "default_attn_backend",
    "default_backend",
    "default_interpret",
    "get_backend",
    "is_packed",
    "pack_linear",
    "partition_kind",
    "plan_for_bits",
    "register_backend",
    "resolve_attn_backend",
    "resolve_backend_name",
    "resolve_plan",
    "validate_bits",
]
