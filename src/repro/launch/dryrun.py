import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run driver.

For one (arch x shape x mesh) cell: build the abstract sharded state, lower
and compile the cell's step function on the production mesh, print
``memory_analysis()`` and ``cost_analysis()``, derive the three roofline
terms, and append the record to a JSON results file.

The two lines above run before ANY other import (jax locks the device count
at first init): the dry-run — and only the dry-run — sees 512 host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
      --shape train_4k [--multi-pod] [--engine-bits 8] [--split-local] \
      [--out experiments/dryrun]
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             engine_bits: int = 0, engine_radix: int = 1, kv_bits: int = 0,
             engine_backend: str = "reference",
             attn_backend: str = "auto",
             engine_sharded: bool = False, psum_bits: int = 0,
             split_local: bool = False, paged: bool = False,
             chunked_prefill: bool = False,
             remat: str = "block",
             microbatches: int = 1, grad_compress_bits: int = 0,
             out_dir: str = "experiments/dryrun", tag: str = "") -> dict:
    import numpy as np

    from repro.config import SHAPES, get_arch
    from repro.config.base import (EngineConfig, MeshConfig, RunConfig,
                                   ServeConfig, TrainConfig)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.roofline.analysis import (model_bytes_for_cell,
                                         model_flops_for_cell,
                                         roofline_report)

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        raise SystemExit(
            f"{arch} is pure full-attention: long_500k is skipped by design "
            "(see DESIGN.md §Arch-applicability)")

    # the 512-host-device dry-run lowers on CPU: pin the exact jnp backend
    # (Pallas TPU kernels do not lower on the CPU backend)
    eng = EngineConfig(weight_bits=engine_bits, radix=engine_radix,
                       kv_bits=kv_bits, backend=engine_backend,
                       attn_backend=attn_backend,
                       sharded=engine_sharded, psum_bits=psum_bits)
    run = RunConfig(
        model=cfg,
        shape=shape,
        mesh=MeshConfig(multi_pod=multi_pod),
        train=TrainConfig(remat=remat, microbatches=microbatches,
                          grad_compress_bits=grad_compress_bits),
        serve=ServeConfig(engine=eng),
    )

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    kw = ({"split_local": split_local, "paged": paged}
          if shape.kind == "decode"
          else {"chunked": chunked_prefill}
          if shape.kind == "prefill" else {})

    from repro.dist import use_mesh

    t0 = time.time()
    with use_mesh(mesh):
        fn, args, kind = build_cell(run, mesh, **kw)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    print(f"=== {arch} x {shape_name} on {mesh.shape} ({kind}) ===")
    try:
        print(compiled.memory_analysis())
    except Exception as e:
        print(f"memory_analysis unavailable: {e}")
    cost = compiled.cost_analysis()
    flops = cost.get("flops") if isinstance(cost, dict) else None
    print({k: v for k, v in (cost.items() if isinstance(cost, dict) else [])
           if k in ("flops", "bytes accessed", "transcendentals")})

    cache_bytes = 0.0
    if kind in ("decode", "prefill"):
        # chunked prefill passes the paged pool at args[1], like decode
        cache_abs = (args[2] if kind == "prefill" and not chunked_prefill
                     else args[1])
        if isinstance(cache_abs, dict):
            leaves = [l for k, sub in cache_abs.items() if k != "pos"
                      for l in jax.tree.leaves(sub)]
        else:  # paged: a KVPages pytree (k/v [+ scale] pools)
            leaves = jax.tree.leaves(cache_abs)
        cache_bytes = float(sum(
            np.prod(l.shape) * l.dtype.itemsize for l in leaves))
    report = roofline_report(
        compiled, n_dev,
        model_flops=model_flops_for_cell(cfg, shape),
        model_bytes=model_bytes_for_cell(cfg, shape, engine_bits,
                                         cache_bytes))
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.shape.values()) if hasattr(mesh.shape, "values")
                else list(mesh.shape),
        "multi_pod": multi_pod,
        "kind": kind,
        "engine_bits": engine_bits,
        "engine_radix": engine_radix,
        "kv_bits": kv_bits,
        "engine_backend": engine_backend if (engine_bits or kv_bits) else "",
        "attn_backend": attn_backend if paged else "",
        "engine_sharded": engine_sharded,
        "psum_bits": psum_bits,
        "split_local": split_local,
        "paged": paged,
        "chunked_prefill": chunked_prefill,
        "remat": remat,
        "microbatches": microbatches,
        "grad_compress_bits": grad_compress_bits,
        "tag": tag,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        **report,
    }
    del flops

    os.makedirs(out_dir, exist_ok=True)
    suffix = "multipod" if multi_pod else "pod"
    name = f"{arch}__{shape_name}__{suffix}"
    if engine_bits:
        name += f"__eng{engine_bits}r{engine_radix}"
    if engine_sharded:
        name += "__sharded"
        if psum_bits:
            name += f"p{psum_bits}"
    if kv_bits:
        name += f"__kv{kv_bits}"
    if split_local:
        name += "__splitlocal"
    if paged:
        name += "__paged"
        if attn_backend != "auto":
            name += f"__attn-{attn_backend}"
    if chunked_prefill:
        name += "__chunked"
    if tag:
        name += f"__{tag}"
    path = os.path.join(out_dir, name + ".json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=float)
    print(f"terms: compute={report['compute_s']:.4e}s "
          f"memory={report['memory_s']:.4e}s "
          f"collective={report['collective_s']:.4e}s "
          f"dominant={report['dominant']} "
          f"roofline_fraction={report.get('roofline_fraction', 0):.3f}")
    print(f"wrote {path}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=[
        "train_4k", "prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--engine-bits", type=int, default=0)
    ap.add_argument("--engine-radix", type=int, default=1)
    ap.add_argument("--kv-bits", type=int, default=0,
                    help="int8 bit-planed KV cache/pages (0 = off)")
    ap.add_argument("--engine-backend", default="reference",
                    help="engine backend registry name (see repro.engine)")
    ap.add_argument("--attn-backend", default="auto",
                    help="paged decode-attention read path: auto | gather "
                         "| pallas_interpret | pallas_tpu")
    ap.add_argument("--engine-sharded", action="store_true",
                    help="wrap the backend in the mesh-native 'sharded' "
                         "dispatch (shard_map over the model axis)")
    ap.add_argument("--psum-bits", type=int, default=0,
                    help="row-parallel partial-GEMV reduction: 0 = fp32 "
                         "psum, 4/8 = compressed codes")
    ap.add_argument("--split-local", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="lower the paged-KV block-table decode cell")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="lower the scheduler's per-step chunked-prefill "
                         "cell (paged pool + per-lane pos0/seq_lens) "
                         "instead of one-shot prefill")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
             engine_bits=args.engine_bits, engine_radix=args.engine_radix,
             kv_bits=args.kv_bits, engine_backend=args.engine_backend,
             attn_backend=args.attn_backend,
             engine_sharded=args.engine_sharded, psum_bits=args.psum_bits,
             split_local=args.split_local, paged=args.paged,
             chunked_prefill=args.chunked_prefill,
             remat=args.remat,
             microbatches=args.microbatches,
             grad_compress_bits=args.grad_compress_bits,
             out_dir=args.out, tag=args.tag)


if __name__ == "__main__":
    main()
