"""Production mesh geometry.

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module touches no jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init and then calls it.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries
data parallelism by default (gradient all-reduce over DCI) and can be
switched to pipeline parallelism in config.
"""

from __future__ import annotations

import jax

from repro.dist.hints import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_mesh((data, model), ("data", "model"))
