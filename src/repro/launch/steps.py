"""Per-cell step builders: abstract (no-allocation) state + the jitted step
function each (arch x shape) dry-run cell lowers.

  train_4k              -> train_step (forward + backward + AdamW)
  prefill_32k           -> prefill    (forward + KV/state cache write)
  decode_32k / long_500k -> serve_step (one token against a seq_len cache)

All state is ``jax.ShapeDtypeStruct`` with ``NamedSharding`` attached — the
dry-run never allocates a parameter.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import EngineConfig, ModelConfig, RunConfig, ShapeConfig
from repro.data.pipeline import synthetic_batch_specs
from repro.dist.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    pool_pages_for_mesh,
)
from repro.engine import resolve_attn_backend, resolve_plan
from repro.models import decode_step, decode_step_paged, init_cache, init_params
from repro.models.transformer import prefill, prefill_chunk, quantize_params
from repro.serve.pages import init_kv_pages, pages_for
from repro.optim import make_optimizer
from repro.train.trainer import make_train_step

Pytree = Any


def _attach(tree: Pytree, shardings: Pytree) -> Pytree:
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings,
    )


def abstract_params(cfg: ModelConfig, engine_bits: int = 0) -> Pytree:
    out = jax.eval_shape(functools.partial(init_params, cfg),
                         jax.random.PRNGKey(0))
    if engine_bits:
        out = jax.eval_shape(
            functools.partial(quantize_params, cfg=cfg, bits=engine_bits), out)
    return out


def sharded_abstract_params(cfg: ModelConfig, mesh, engine_bits: int = 0):
    ap = abstract_params(cfg, engine_bits)
    return _attach(ap, param_shardings(mesh, ap))


def train_cell(run: RunConfig, mesh) -> Tuple[Any, Tuple]:
    """Returns (jitted_fn, abstract_args) for a training cell."""
    cfg, shape, tcfg = run.model, run.shape, run.train
    ap = abstract_params(cfg)
    # training params/optimizer are fully sharded (ZeRO/FSDP over the data
    # axes on top of TP) — 100B+ configs cannot fit TP-only state.
    ap_sh = _attach(ap, param_shardings(mesh, ap, mode="fsdp"))

    init_fn, _ = make_optimizer(tcfg.optimizer)
    aopt = jax.eval_shape(init_fn, ap)
    aopt_sh = _attach(aopt, opt_state_shardings(mesh, aopt, mode="fsdp"))

    if tcfg.grad_compress_bits:
        from repro.optim import ef_state_init

        aef = jax.eval_shape(ef_state_init, ap)
        aef_sh = _attach(aef, opt_state_shardings(mesh, aef, mode="fsdp"))
    else:
        aef_sh = {}

    text_seq = (shape.seq_len - cfg.img_tokens if cfg.family == "vlm"
                else shape.seq_len)
    abatch = synthetic_batch_specs(cfg, shape.global_batch, text_seq)
    abatch_sh = _attach(abatch, batch_shardings(mesh, abatch))

    fn = make_train_step(cfg, tcfg, donate=True)
    return fn, (ap_sh, aopt_sh, aef_sh, abatch_sh)


def prefill_cell(run: RunConfig, mesh) -> Tuple[Any, Tuple]:
    cfg, shape = run.model, run.shape
    # resolved once per cell, mesh pinned (sharded backends shard_map it)
    plan = resolve_plan(run.serve.engine, mesh=mesh)
    bits = plan.bits if plan else 0
    ap_sh = sharded_abstract_params(cfg, mesh, bits)

    seq = shape.seq_len
    text_seq = seq - cfg.img_tokens if cfg.family == "vlm" else seq
    abatch = synthetic_batch_specs(cfg, shape.global_batch, text_seq)
    abatch.pop("labels")
    abatch_sh = _attach(abatch, batch_shardings(mesh, abatch))

    acache = jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, seq))
    acache_sh = _attach(acache, cache_shardings(mesh, acache))

    fn = jax.jit(
        lambda params, batch, cache: prefill(params, batch, cfg, cache, plan),
        donate_argnums=(2,),
    )
    return fn, (ap_sh, abatch_sh, acache_sh)


def chunked_prefill_cell(run: RunConfig, mesh) -> Tuple[Any, Tuple]:
    """The serving-path prefill: one batched chunk of prompt prefill
    against the paged page pool — exactly what the paged/budget
    schedulers lower per engine step.  Lanes carry independent
    ``pos0``/``seq_lens`` (a 30k-token prompt is sliced across many of
    these calls while other lanes decode), so this one compiled cell
    covers every admission mix the scheduler can produce."""
    cfg, shape = run.model, run.shape
    # resolved once per cell, mesh pinned (sharded backends shard_map it)
    plan = resolve_plan(run.serve.engine, mesh=mesh)
    bits = plan.bits if plan else 0
    ap_sh = sharded_abstract_params(cfg, mesh, bits)

    kv_bits = plan.kv_bits if plan else 0
    b = shape.global_batch
    page_size = run.serve.page_size
    chunk = run.serve.prefill_chunk
    n_blocks = pages_for(shape.seq_len, page_size)
    n_pages = pool_pages_for_mesh(
        run.serve.n_pages or b * n_blocks + 1, mesh)
    apages = jax.eval_shape(functools.partial(
        init_kv_pages, cfg, n_pages, page_size, kv_bits=kv_bits))
    apages_sh = _attach(apages, cache_shardings(mesh, apages))

    # host-built index state: lane axis over the data axes
    tok_shape = ((b, chunk, cfg.n_codebooks) if cfg.family == "audio"
                 else (b, chunk))
    aidx = {
        "block_tables": jax.ShapeDtypeStruct((b, n_blocks), jnp.int32),
        "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "pos0": jax.ShapeDtypeStruct((b,), jnp.int32),
        "seq_lens": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
    aidx_sh = _attach(aidx, batch_shardings(mesh, aidx))

    fn = jax.jit(
        lambda params, pages, bt, tokens, pos0, seq_lens: prefill_chunk(
            params, pages, bt, tokens, pos0, seq_lens, cfg, plan),
        donate_argnums=(1,),
    )
    return fn, (ap_sh, apages_sh, aidx_sh["block_tables"],
                aidx_sh["tokens"], aidx_sh["pos0"], aidx_sh["seq_lens"])


def paged_serve_cell(run: RunConfig, mesh) -> Tuple[Any, Tuple]:
    """Decode against the paged-KV page pool (the continuous-batching
    serving layout): block-table gather instead of a per-slot cache
    rectangle, sized here at full capacity for the cell's batch."""
    cfg, shape = run.model, run.shape
    # resolved once per cell, mesh pinned (sharded backends shard_map it)
    plan = resolve_plan(run.serve.engine, mesh=mesh)
    bits = plan.bits if plan else 0
    ap_sh = sharded_abstract_params(cfg, mesh, bits)

    kv_bits = plan.kv_bits if plan else 0
    b = shape.global_batch
    page_size = run.serve.page_size
    n_blocks = pages_for(shape.seq_len, page_size)
    # pad the pool so the physical page axis shards over the data axes
    n_pages = pool_pages_for_mesh(
        run.serve.n_pages or b * n_blocks + 1, mesh)
    apages = jax.eval_shape(functools.partial(
        init_kv_pages, cfg, n_pages, page_size, kv_bits=kv_bits))
    apages_sh = _attach(apages, cache_shardings(mesh, apages))

    # host-built index state: lane axis over the data axes
    aidx = {
        "block_tables": jax.ShapeDtypeStruct((b, n_blocks), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        "active": jax.ShapeDtypeStruct((b,), jnp.bool_),
    }
    aidx_sh = _attach(aidx, batch_shardings(mesh, aidx))
    tok_shape = ((b, 1, cfg.n_codebooks) if cfg.family == "audio"
                 else (b, 1))
    atoks = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    atoks_sh = _attach(atoks, batch_shardings(mesh, atoks))["tokens"]

    # when the engine itself is disabled the plan is None, but the config
    # still names a decode-attention read path (gather vs fused kernel);
    # resolved eagerly (typos fail here, "auto" on a mesh stays gather)
    abk = (plan.attn_backend if plan
           else resolve_attn_backend(
               getattr(run.serve.engine, "attn_backend", None), mesh=mesh))
    fn = jax.jit(
        lambda params, pages, bt, pos, active, tokens: decode_step_paged(
            params, pages, bt, pos, active, tokens, cfg, plan,
            attn_backend=abk),
        donate_argnums=(1,),
    )
    return fn, (ap_sh, apages_sh, aidx_sh["block_tables"],
                aidx_sh["pos"], aidx_sh["active"], atoks_sh)


def serve_cell(run: RunConfig, mesh, split_local: bool = False,
               stacked: bool = False, paged: bool = False) -> Tuple[Any, Tuple]:
    """Decode cells default to the unstacked per-layer cache layout (no
    stacked scan carry — the production decode graph).  ``paged=True``
    lowers the paged-KV block-table layout instead."""
    if paged:
        return paged_serve_cell(run, mesh)
    cfg, shape = run.model, run.shape
    # resolved once per cell, mesh pinned (sharded backends shard_map it)
    plan = resolve_plan(run.serve.engine, mesh=mesh)
    bits = plan.bits if plan else 0
    ap_sh = sharded_abstract_params(cfg, mesh, bits)

    kv_bits = plan.kv_bits if plan else 0
    acache = jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, shape.seq_len,
                          split_local=split_local, stacked=stacked,
                          kv_bits=kv_bits))
    acache_sh = _attach(acache, cache_shardings(mesh, acache))

    tok_shape = ((shape.global_batch, 1, cfg.n_codebooks)
                 if cfg.family == "audio" else (shape.global_batch, 1))
    atoks = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    atoks_sh = _attach(atoks, batch_shardings(mesh, atoks))["tokens"]

    fn = jax.jit(
        lambda params, cache, tokens: decode_step(params, cache, tokens, cfg,
                                                  plan),
        donate_argnums=(1,),
    )
    return fn, (ap_sh, acache_sh, atoks_sh)


def build_cell(run: RunConfig, mesh, **kw) -> Tuple[Any, Tuple, str]:
    """(fn, abstract_args, kind) for the run's shape cell."""
    kind = run.shape.kind
    if kind == "train":
        fn, args = train_cell(run, mesh)
    elif kind == "prefill":
        if kw.pop("chunked", False):
            fn, args = chunked_prefill_cell(run, mesh)
        else:
            fn, args = prefill_cell(run, mesh, **kw)
    elif kind == "decode":
        fn, args = serve_cell(run, mesh, **kw)
    else:
        raise ValueError(kind)
    return fn, args, kind
