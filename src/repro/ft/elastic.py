"""Elastic scaling: shrink/regrow the data-parallel extent on node loss.

Shrinking strategy (standard for pod-scale runs): the ``model`` axis is
never resized (weight shards would need re-layout); capacity loss removes
whole data-parallel replicas — from (pod=2, data=16, model=16) to
(pod=1, data=16, model=16) or (data=8, model=16) etc.  Because every DP
replica holds identical params/optimizer state, resharding is a pure
re-placement: no state is lost, only per-replica batch slices are
re-assigned.  The global batch is preserved by raising the per-replica
microbatch count (gradient accumulation) so optimization is bit-comparable
before/after the shrink.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax

from repro.config.base import MeshConfig


@dataclass
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    grad_accum_factor: int  # multiply microbatches by this to keep global batch


class ElasticMeshManager:
    def __init__(self, mesh_cfg: MeshConfig):
        self.cfg = mesh_cfg

    def plan_shrink(self, lost_nodes: int, chips_per_node: int = 4) -> ElasticPlan:
        """Compute the largest valid mesh after losing ``lost_nodes``."""
        shape = list(self.cfg.shape)
        names = list(self.cfg.axis_names)
        lost_chips = lost_nodes * chips_per_node
        total = 1
        for s in shape:
            total *= s
        remaining = total - lost_chips
        if remaining <= 0:
            raise ValueError("no capacity left")

        model = shape[-1]                      # never resized
        data_like = remaining // model
        if data_like < 1:
            raise ValueError("cannot keep model axis intact")

        # collapse pod*data to the largest power-of-two <= data_like
        new_data = 1 << (data_like.bit_length() - 1)
        old_data = total // model
        factor = old_data // new_data
        if len(shape) == 3:
            # fold into (data, model) if a whole pod was lost, else shrink data
            if new_data % shape[1] == 0 and new_data // shape[1] >= 1:
                new_shape = (new_data // shape[1], shape[1], model)
                new_names = tuple(names)
            else:
                new_shape = (new_data, model)
                new_names = (names[1], names[2])
        else:
            new_shape = (new_data, model)
            new_names = tuple(names)
        return ElasticPlan(tuple(shape), new_shape, new_names, factor)

    @staticmethod
    def reshard(tree, old_mesh, new_mesh, spec_fn):
        """Re-place a pytree from old_mesh onto new_mesh.

        With DP-only shrinkage every leaf's PartitionSpec is valid on both
        meshes; jax.device_put handles the physical move.
        """
        from jax.sharding import NamedSharding

        def move(path_leaf):
            path, leaf = path_leaf
            spec = spec_fn(path, leaf)
            return jax.device_put(leaf, NamedSharding(new_mesh, spec))

        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        return treedef.unflatten([move(pl) for pl in flat])
