from repro.ft.failures import FailureInjector, RestartPolicy
from repro.ft.chaos import ChaosInjector, SimulatedStepFailure
from repro.ft.elastic import ElasticMeshManager
from repro.ft.straggler import StragglerMonitor

__all__ = [
    "FailureInjector",
    "RestartPolicy",
    "ChaosInjector",
    "SimulatedStepFailure",
    "ElasticMeshManager",
    "StragglerMonitor",
]
