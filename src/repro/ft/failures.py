"""Failure handling: injection (for drills), detection, restart policy.

At 1000+ nodes, node loss is routine: the design here is the standard
checkpoint/restart loop hardened for it —

  detect (heartbeat timeout / XLA error)  ->  classify  ->  either
  (a) restart-in-place from the latest committed checkpoint, or
  (b) elastic shrink (repro/ft/elastic.py) when capacity is lost.

This module is deliberately runnable on one CPU: ``FailureInjector``
deterministically raises ``SimulatedNodeFailure`` inside the step loop so
tests/drills exercise the same recovery path a real run would take.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.obs import clock


class SimulatedNodeFailure(RuntimeError):
    def __init__(self, node_id: int, step: int):
        super().__init__(f"node {node_id} failed at step {step}")
        self.node_id = node_id
        self.step = step


@dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: node_id}."""

    schedule: dict = field(default_factory=dict)

    def check(self, step: int) -> None:
        if step in self.schedule:
            node = self.schedule.pop(step)
            raise SimulatedNodeFailure(node, step)


@dataclass
class RestartPolicy:
    """Bounded-retry restart with exponential backoff (capped).

    ``reset_after_steps`` makes the budget recover: if that many steps
    pass between failures, the restart counter resets to zero before
    the new failure is counted.  Without it a long-lived process (a
    serve engine handling weeks of traffic) would exhaust the budget
    from faults that are hours apart — the budget should bound failure
    *density*, not lifetime total.  0 disables the reset (the training
    loop's original accumulate-forever behavior).
    """

    max_restarts: int = 5
    backoff_s: float = 0.01
    backoff_cap_s: float = 1.0
    reset_after_steps: int = 0
    restarts: int = 0
    last_failure_step: int = -1

    def on_failure(self, exc: Exception, step: int) -> float:
        """Returns backoff seconds before restart; raises if budget spent."""
        if (self.reset_after_steps > 0 and self.last_failure_step >= 0
                and step - self.last_failure_step >= self.reset_after_steps):
            self.restarts = 0
        self.restarts += 1
        self.last_failure_step = step
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"restart budget exhausted after {self.restarts - 1} restarts"
            ) from exc
        return min(self.backoff_s * (2 ** (self.restarts - 1)),
                   self.backoff_cap_s)


def run_with_restarts(
    step_fn: Callable[[int], None],
    *,
    start_step: int,
    total_steps: int,
    restore_fn: Callable[[], int],
    policy: Optional[RestartPolicy] = None,
    injector: Optional[FailureInjector] = None,
) -> int:
    """Drive ``step_fn`` with checkpoint/restart semantics.

    ``restore_fn`` must rewind all mutable state (params/opt/data) to the
    latest committed checkpoint and return its step.  Returns the number of
    restarts performed.
    """
    policy = policy or RestartPolicy()
    step = start_step
    while step < total_steps:
        try:
            if injector is not None:
                injector.check(step)
            step_fn(step)
            step += 1
        except (SimulatedNodeFailure, RuntimeError) as exc:
            if isinstance(exc, RuntimeError) and not isinstance(
                exc, SimulatedNodeFailure
            ):
                raise
            delay = policy.on_failure(exc, step)
            clock.sleep(delay)
            step = restore_fn()
    return policy.restarts
