"""Straggler detection and mitigation.

On synchronous SPMD hardware a straggling host delays every collective; at
1000+ nodes a persistent straggler costs its slowdown fleet-wide.  The
monitor keeps an EWMA + robust deviation of step times (per host when
timings are reported per host) and flags hosts/steps exceeding
``threshold`` x the fleet median.  Mitigations (configurable):

  * "flag"      — report only (default; feeds the ops pager)
  * "skip"      — drop the straggler's microbatch contribution this step
                  (gradient re-weighted by the surviving replica count;
                  bounded staleness, standard backup-worker trick)
  * "evict"     — request an elastic shrink via repro/ft/elastic.py

The detector is pure python over reported timings, so it is fully testable
without hardware.
"""

from __future__ import annotations

import statistics
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional


@dataclass
class StragglerEvent:
    step: int
    host: int
    step_time: float
    median: float
    ratio: float
    action: str


@dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x median
    window: int = 32
    patience: int = 3               # consecutive flags before mitigation
    mitigation: str = "flag"        # flag | skip | evict
    _times: Dict[int, Deque[float]] = field(
        default_factory=lambda: defaultdict(lambda: deque(maxlen=32)))
    _flags: Dict[int, int] = field(default_factory=lambda: defaultdict(int))
    events: List[StragglerEvent] = field(default_factory=list)

    def observe(self, step: int, host_times: Dict[int, float]) -> List[StragglerEvent]:
        """Feed one step's per-host times; returns new events."""
        med = statistics.median(host_times.values())
        new: List[StragglerEvent] = []
        for host, t in host_times.items():
            self._times[host].append(t)
            ratio = t / med if med > 0 else 1.0
            if ratio > self.threshold:
                self._flags[host] += 1
            else:
                self._flags[host] = 0
            if self._flags[host] >= self.patience:
                action = self.mitigation
                ev = StragglerEvent(step, host, t, med, ratio, action)
                self.events.append(ev)
                new.append(ev)
                self._flags[host] = 0
        return new

    def chronic_hosts(self) -> List[int]:
        """Hosts whose median time exceeds threshold x fleet median."""
        if not self._times:
            return []
        host_meds = {h: statistics.median(ts) for h, ts in self._times.items()
                     if ts}
        fleet = statistics.median(host_meds.values())
        return [h for h, m in host_meds.items()
                if fleet > 0 and m / fleet > self.threshold]
