"""Deterministic chaos injection for the *serving* path.

``repro.ft.failures`` gave the training loop a seeded failure drill;
this module is the serving analogue.  At production scale faults are
routine traffic — a page grant fails, a device step errors, a logit
goes non-finite, a tenant cancels ten thousand streams at once — and
the serve stack must degrade per-request, never per-process.  The
:class:`ChaosInjector` makes those faults *first-class, replayable
inputs*: every hook site in the stack asks ``chaos.fire(site)`` at its
decision point, and the injector answers deterministically from either
an explicit **schedule** (fire at the nth check of a site) or a seeded
**rate** (an independent pseudo-random draw per check, keyed by
``(seed, site, check_index)`` so the answer does not depend on thread
timing or call interleaving across sites).

Hook sites (the ``SITES`` tuple; each named constant documents where
the stack consults it):

* ``page_grant``   — ``PageAllocator._take_page``: the pop fails as if
  the pool were exhausted (admission blocks / decode preempts — the
  normal dry-pool paths, exercised on demand).
* ``step_fault``   — ``ServeEngine`` prefill/decode dispatch: one
  participating lane takes a :class:`SimulatedStepFailure` (the
  serving analogue of ``SimulatedNodeFailure``).
* ``nan_logits``   — ``ServeEngine`` after a dispatch lands: one
  lane's fresh logits are overwritten with NaN, exercising the
  non-finite quarantine path end to end.
* ``preempt_storm``— ``ServeEngine`` step: every resident request is
  preempted at once (recompute-style, token-preserving).
* ``cancel``       — ``ServeFrontend.step``: one live stream is
  cancelled (a client hanging up mid-generation).
* ``deadline_skew``— ``ServeFrontend.step``: the deadline sweep sees a
  skewed clock (``skew_s`` into the future), firing timeouts early.

Every fired event is appended to ``self.log`` as ``(site, index)``, so
a drill can assert that two runs with the same seed injected the exact
same faults — determinism is what makes a chaos failure *debuggable*.
"""

from __future__ import annotations

import random
from typing import Dict, Iterable, List, Optional, Tuple

# hook sites, in stack order (allocator -> engine -> scheduler -> frontend)
PAGE_GRANT = "page_grant"
STEP_FAULT = "step_fault"
NAN_LOGITS = "nan_logits"
PREEMPT_STORM = "preempt_storm"
CANCEL = "cancel"
DEADLINE_SKEW = "deadline_skew"

SITES = (PAGE_GRANT, STEP_FAULT, NAN_LOGITS, PREEMPT_STORM, CANCEL,
         DEADLINE_SKEW)


class SimulatedStepFailure(RuntimeError):
    """A serving step failed for one lane (injected device error)."""

    def __init__(self, slot: int, rid: int):
        super().__init__(f"simulated step failure: lane {slot} rid {rid}")
        self.slot = slot
        self.rid = rid


class ChaosInjector:
    """Seeded / scheduled fault source for the serving stack.

    ``schedule``: ``{site: iterable of check indices}`` — the site
    fires exactly at those occurrences of its check (0-based: the
    first ``fire(site)`` call is check 0).  ``rates``: ``{site:
    probability}`` — each check draws independently from a generator
    seeded by ``(seed, site, check_index)``.  A site may appear in
    both; the schedule fires first (no double-count).  ``skew_s`` is
    the clock skew applied when ``deadline_skew`` fires.

    The injector is single-run state (check counters, fired log);
    build a fresh one with the same arguments to replay a run.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 schedule: Optional[Dict[str, Iterable[int]]] = None,
                 skew_s: float = 0.0):
        self.seed = seed
        self.rates = dict(rates or {})
        self.schedule = {site: set(idx) for site, idx
                         in (schedule or {}).items()}
        self.skew_s = skew_s
        for site in list(self.rates) + list(self.schedule):
            if site not in SITES:
                raise ValueError(
                    f"unknown chaos site {site!r}; choose from {SITES}")
        self._counts: Dict[str, int] = {}
        self.log: List[Tuple[str, int]] = []
        # observability sink: the owning engine points this at its
        # telemetry (ServeEngine sets chaos.obs = self.obs) so every
        # fired fault — including allocator-internal sites like
        # ``page_grant`` — lands in the metrics registry without each
        # call site having to report separately.  None = unobserved.
        self.obs = None

    # ------------------------------------------------------------ decisions
    def _rng(self, site: str, idx: int, salt: str = "") -> random.Random:
        # string seeds hash through sha512 — stable across processes
        # (tuple seeds go through hash(), which PYTHONHASHSEED perturbs)
        return random.Random(f"{self.seed}/{site}/{idx}/{salt}")

    def count(self, site: str) -> int:
        """How many times ``site`` has been checked so far."""
        return self._counts.get(site, 0)

    def fire(self, site: str) -> bool:
        """One check of ``site``: does the fault fire now?

        Deterministic in ``(seed, site, check index)`` only — the
        answer is independent of what any other site did, so a run
        replays exactly even when the stack's call order across sites
        shifts (e.g. an earlier fault changes how many lanes decode).
        """
        if site not in SITES:
            raise ValueError(
                f"unknown chaos site {site!r}; choose from {SITES}")
        idx = self._counts.get(site, 0)
        self._counts[site] = idx + 1
        fired = idx in self.schedule.get(site, ())
        if not fired:
            rate = self.rates.get(site, 0.0)
            if rate > 0.0:
                fired = self._rng(site, idx).random() < rate
        if fired:
            self.log.append((site, idx))
            if self.obs is not None:
                self.obs.on_chaos(site)
        return fired

    def pick(self, site: str, n: int) -> int:
        """Deterministic victim index in ``[0, n)`` for the fault that
        just fired at ``site`` (keyed by the *fired* check index, so a
        replay picks the same victim)."""
        if n <= 0:
            raise ValueError("pick() needs a non-empty victim set")
        idx = self._counts.get(site, 1) - 1
        return self._rng(site, idx, "pick").randrange(n)

    # ------------------------------------------------------------- reports
    def fired(self, site: Optional[str] = None) -> int:
        """Total faults fired (for ``site``, or overall)."""
        if site is None:
            return len(self.log)
        return sum(1 for s, _ in self.log if s == site)

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s, _ in self.log:
            out[s] = out.get(s, 0) + 1
        return out
